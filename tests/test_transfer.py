"""Cold-start cross-job transfer: similarity properties (symmetric,
permutation-invariant, self-maximal — for ARBITRARY runtime datasets, not
just the emulated Spark jobs), version-keyed lookup caching, and the
gateway fallback that serves unknown / under-supported jobs from the
nearest donor's models with transfer-stamped envelopes."""
import asyncio

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # deterministic example sweeps
    from _hyp_fallback import given, settings, strategies as st

from repro.api import codec
from repro.api.gateway import AsyncHubGateway, HubGateway
from repro.api.types import ChooseRequest, PredictRequest
from repro.core.datastore import RuntimeDataStore
from repro.core.features import JobSchema, RuntimeData
from repro.core.hub import Hub, JobRepo
from repro.core.transfer import (TransferIndex, TransferPolicy,
                                 job_signature, similarity)
from repro.workloads import spark_emul as W

SCALEOUTS = (2, 3, 4, 6, 8, 12)
PRICES = {m.name: m.price for m in W.MACHINES.values()}


def _random_data(rng: np.random.Generator, n: int, k: int,
                 job: str = "prop") -> RuntimeData:
    schema = JobSchema(job, tuple(f"c{i}" for i in range(k)))
    names = [f"m{i}" for i in range(int(rng.integers(1, 4)))]
    machine_type = np.asarray(names)[rng.integers(0, len(names), size=n)]
    X = np.empty((n, schema.n_features))
    X[:, 0] = rng.integers(1, 64, size=n)                 # scale-out
    X[:, 1:] = rng.uniform(0.05, 1000.0, size=(n, k + 1))  # size + context
    y = rng.uniform(0.05, 5000.0, size=n)
    return RuntimeData(schema, machine_type, X, y)


# --------------------------------------------------------------------------
# similarity properties
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), m=st.integers(1, 60), k=st.integers(0, 3),
       seed=st.integers(0, 10**6))
def test_similarity_symmetric_and_bounded(n, m, k, seed):
    rng = np.random.default_rng(seed)
    a = job_signature(_random_data(rng, n, k, "a"))
    b = job_signature(_random_data(rng, m, k, "b"))
    assert similarity(a, b) == similarity(b, a)
    assert 0.0 <= similarity(a, b) <= 1.0


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 60), k=st.integers(0, 3), seed=st.integers(0, 10**6))
def test_signature_invariant_under_row_permutation(n, k, seed):
    """Contribution order must not move a job in signature space: the
    sketch of any row permutation is the EXACT same signature (quantiles
    and histograms are permutation-free; machine lists are sorted)."""
    rng = np.random.default_rng(seed)
    d = _random_data(rng, n, k)
    perm = rng.permutation(n)
    assert job_signature(d.subset(perm), "j") == job_signature(d, "j")


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), m=st.integers(1, 60), k=st.integers(0, 3),
       seed=st.integers(0, 10**6))
def test_self_similarity_is_maximal(n, m, k, seed):
    rng = np.random.default_rng(seed)
    a = job_signature(_random_data(rng, n, k, "a"))
    b = job_signature(_random_data(rng, m, k, "b"))
    assert similarity(a, a) == pytest.approx(1.0)
    assert similarity(a, a) >= similarity(a, b) - 1e-12


def test_incompatible_context_widths_never_match_well():
    rng = np.random.default_rng(0)
    a = job_signature(_random_data(rng, 40, 0, "a"))
    b = job_signature(_random_data(rng, 40, 2, "b"))
    # no context component can contribute across schema widths
    assert similarity(a, b) <= 0.7


def test_emulated_cold_probes_match_their_own_family():
    """The discrimination claim behind the whole subsystem: each cold
    twin's few probe rows rank the SAME family first among all
    schema-compatible donors — including sgd/kmeans/pagerank, which share
    a feature count."""
    sigs = {j: job_signature(W.generate_job_data(j, 0), j)
            for j in W.SCHEMAS}
    for job in W.SCHEMAS:
        probe = job_signature(W.cold_probe(job, 0))
        scores = {d: similarity(probe, s) for d, s in sigs.items()
                  if s.n_features == probe.n_features}
        assert max(scores, key=scores.get) == job, (job, scores)


# --------------------------------------------------------------------------
# TransferIndex: version-keyed caching + lookup semantics
# --------------------------------------------------------------------------

def _fixture_hub(cold_rows=True):
    hub = Hub()
    for job in ("grep", "sort"):
        d = W.generate_job_data(job, seed=0)
        hub.publish(JobRepo(job, job, d.schema, RuntimeDataStore(d, seed=0)))
    if cold_rows:
        hub.publish(JobRepo(
            "grep-cold", "grep (cold twin)", W.cold_schema("grep"),
            RuntimeDataStore(W.cold_probe("grep", 0), seed=0)))
    return hub


def test_nearest_picks_schema_compatible_donor_with_confidence_discount():
    hub = _fixture_hub()
    pol = TransferPolicy()
    match = hub.nearest_job("grep-cold", policy=pol)
    assert match.source == "grep"                 # sort has the wrong width
    assert 0.0 < match.similarity <= 1.0
    assert match.confidence == pytest.approx(match.similarity * pol.discount)


def test_nearest_for_rowless_job_uses_prior_confidence():
    hub = _fixture_hub(cold_rows=False)
    pol = TransferPolicy()
    match = hub.nearest_job("never-seen", n_features=3, policy=pol)
    assert match.source == "grep"
    assert match.similarity == 0.0
    assert match.confidence == pytest.approx(pol.unknown_prior * pol.discount)
    # and with no schema hint, the best-supported store wins
    assert hub.nearest_job("never-seen").source in ("grep", "sort")


def test_lookup_caches_amortize_across_unchanged_store_versions():
    hub = _fixture_hub()
    index = hub.transfer_index(TransferPolicy())
    index.nearest("grep-cold")
    builds = index.stats["signature_builds"]
    pairs = index.stats["pair_evals"]
    for _ in range(5):
        assert index.nearest("grep-cold").source == "grep"
    assert index.stats["signature_builds"] == builds     # all cache hits
    assert index.stats["pair_evals"] == pairs
    # an accepted contribution moves the store version -> exactly the
    # changed job re-sketches and its pairs recompute
    repo = hub.get("grep")
    extra = W.generate_user_data("grep", user=9, seed=3)
    assert repo.store.contribute(extra).accepted
    assert index.nearest("grep-cold").source == "grep"
    assert index.stats["signature_builds"] == builds + 1
    assert index.stats["pair_evals"] == pairs + 1


# --------------------------------------------------------------------------
# gateway cold-start fallback
# --------------------------------------------------------------------------

@pytest.fixture()
def tgw():
    return HubGateway(_fixture_hub(), PRICES, SCALEOUTS,
                      transfer=TransferPolicy())


def test_under_supported_job_borrows_with_transfer_stamped_envelope(tgw):
    X = ((4.0, 15.0, 0.02),)
    resp = tgw.predict(PredictRequest("grep-cold", "m5.xlarge", X))
    assert resp.ok
    assert resp.result.transfer_source == "grep"
    assert 0.0 < resp.result.transfer_confidence < 1.0
    # the borrowed prediction IS the donor's (same model, same runtimes)
    donor = tgw.predict(PredictRequest("grep", "m5.xlarge", X))
    assert donor.result.runtimes_s == resp.result.runtimes_s
    assert donor.result.selected_model == resp.result.selected_model
    # ... but the donor's own envelope carries no transfer fields on the
    # wire, while the borrowed one does
    assert "transfer_source" not in codec.encode(donor)
    assert '"transfer_source":"grep"' in codec.encode(resp)


def test_unknown_job_borrows_instead_of_erroring(tgw):
    resp = tgw.predict(PredictRequest(
        "never-seen", "m5.xlarge", ((4.0, 15.0, 0.02),)))
    assert resp.ok and resp.result.transfer_source in ("grep", "sort")
    pol = tgw.transfer
    assert resp.result.transfer_confidence == pytest.approx(
        pol.unknown_prior * pol.discount)


def test_choose_borrows_and_matches_donor_choice(tgw):
    ctx = (15.0, 0.02)
    resp = tgw.choose(ChooseRequest("grep-cold", ctx, t_max=400.0))
    assert resp.ok and resp.result.transfer_source == "grep"
    donor = tgw.choose(ChooseRequest("grep", ctx, t_max=400.0))
    assert (resp.result.machine_type, resp.result.scale_out) == \
        (donor.result.machine_type, donor.result.scale_out)


def test_transfer_disabled_by_default_and_no_donor_still_errors():
    hub = _fixture_hub()
    gw = HubGateway(hub, PRICES, SCALEOUTS)     # no policy: old behavior
    resp = gw.predict(PredictRequest(
        "never-seen", "m5.xlarge", ((4.0, 15.0, 0.02),)))
    assert resp.error_code == "unknown_job"
    # transfer on, but no schema-compatible donor published: typed error,
    # not a nonsense borrow
    tgw = HubGateway(hub, PRICES, SCALEOUTS, transfer=TransferPolicy())
    wide = tgw.predict(PredictRequest(
        "never-seen", "m5.xlarge", ((4.0, 1.0, 2.0, 3.0, 4.0),)))
    assert wide.error_code == "unknown_job"


def test_borrowed_machine_must_exist_in_donor_store(tgw):
    resp = tgw.predict(PredictRequest(
        "grep-cold", "warp-drive", ((4.0, 15.0, 0.02),)))
    assert resp.error_code == "bad_request"
    assert "warp-drive" in resp.detail and "grep-cold" in resp.detail


def test_async_borrowed_lane_keyed_on_source_and_matches_inline(tgw):
    """Borrowed single-row predicts batch on a source-keyed lane and the
    envelopes are byte-identical to the sync path."""
    X = ((4.0, 15.0, 0.02),)
    inline = tgw.predict(PredictRequest("grep-cold", "m5.xlarge", X))

    async def drive():
        async with AsyncHubGateway(tgw, tick_s=0.002) as agw:
            got = await asyncio.gather(*(
                agw.predict(PredictRequest("grep-cold", "m5.xlarge", X))
                for _ in range(8)))
            return got, dict(agw.lane_stats)

    got, lanes = asyncio.run(drive())
    assert list(lanes) == ["grep-cold@m5.xlarge<-grep"]
    assert lanes["grep-cold@m5.xlarge<-grep"].requests == 8
    for resp in got:
        assert codec.encode(resp) == codec.encode(inline)


def test_cold_replay_mini_is_deterministic_and_beats_mean_baseline():
    """One-family micro version of ``--cold-start-job``: byte-identical
    reruns, and the borrowed model beats the global-mean baseline."""
    from repro.eval.replay import ColdStartConfig, run_cold_start
    cfg = ColdStartConfig(jobs=("grep",), n_users=2, seed=0)
    a = run_cold_start(cfg)
    b = run_cold_start(cfg)
    assert a.tsv == b.tsv and a.fingerprint == b.fingerprint
    s = a.summary["grep"]
    assert s["sources"] == ["grep"]
    assert s["beats_mean"] and a.ok
