"""Trust-plane contracts: token-bucket quotas, the reputation ledger,
reputation-aware store validation, and the gateway's auth/quota/ban
admission surface — refusals are typed error envelopes, never exceptions,
and a refused contributor cannot move any store's fingerprint chain."""
import asyncio
import time

import numpy as np
import pytest

from repro.api import (AsyncHubGateway, AuthedRequest, ChooseRequest,
                       ContributeRequest, HubGateway, SearchRequest,
                       TrustAuthority, TrustStateRequest)
from repro.core.datastore import RuntimeDataStore, ValidationReport
from repro.core.features import RuntimeData
from repro.core.hub import Hub, JobRepo
from repro.core.trust import ReputationLedger, TokenBucket
from repro.serve.config_service import BatchLane, LaneTimeoutError
from repro.workloads import spark_emul as W

SCALEOUTS = (2, 3, 4, 6, 8, 12, 16)
PRICES = {m.name: m.price for m in W.MACHINES.values()}


def _small_data(job="sort", user=0, seed=1):
    return W.generate_user_data(job, user, seed, n_cells=3, n_scale=3)


# --------------------------------------------------------------------------
# token bucket
# --------------------------------------------------------------------------

def test_token_bucket_burst_then_refill():
    b = TokenBucket(rate=2.0, burst=4.0)
    assert all(b.admit(0.0) for _ in range(4))     # full burst up front
    assert not b.admit(0.0)                        # drained
    assert b.admit(1.0)                            # 1s * 2/s = 2 tokens
    assert b.admit(1.0)
    assert not b.admit(1.0)
    assert b.remaining(1.0) == 0.0


def test_token_bucket_clock_rewind_mints_nothing():
    b = TokenBucket(rate=10.0, burst=3.0)
    assert all(b.admit(100.0) for _ in range(3))
    # a rewinding (or repeating) caller clock must not refill: the
    # origin only moves forward
    for now in (50.0, 0.0, 100.0, 99.9):
        assert not b.admit(now)
    assert b.admit(100.5)                          # real elapsed time does


def test_token_bucket_rejects_nonpositive_parameters():
    for rate, burst in ((0.0, 1.0), (1.0, 0.0), (-1.0, 5.0)):
        with pytest.raises(ValueError):
            TokenBucket(rate, burst)


# --------------------------------------------------------------------------
# reputation ledger
# --------------------------------------------------------------------------

def test_fresh_contributor_is_exactly_neutral():
    led = ReputationLedger()
    assert led.reputation("nobody") == led.NEUTRAL
    assert led.row_weight("nobody") == 1.0
    assert led.threshold_scale("nobody") == 1.0
    assert not led.allows_grace("nobody")
    assert "nobody" not in led


def test_outcomes_move_reputation_weight_and_threshold():
    led = ReputationLedger()
    led.record_outcome("eve", False, 0.0)
    rep1 = led.reputation("eve")
    assert rep1 < led.NEUTRAL
    w1, s1 = led.row_weight("eve"), led.threshold_scale("eve")
    assert led.MIN_ROW_WEIGHT <= w1 < 1.0
    assert led.MIN_THRESHOLD_SCALE <= s1 < 1.0
    led.record_outcome("eve", False, 0.0)
    assert led.reputation("eve") < rep1            # repeat failures sink
    assert led.row_weight("eve") < w1
    assert led.threshold_scale("eve") < s1
    # cubic decay bites early: one failure (rep 1/3) already costs more
    # than the linear trim (weight 1/3 / 0.5 = 0.667 of the span)
    frac = (rep1 / led.NEUTRAL) ** 3
    assert w1 == pytest.approx(
        led.MIN_ROW_WEIGHT + (1 - led.MIN_ROW_WEIGHT) * frac)
    # good standing earns EQUAL trust, never extra leverage
    for _ in range(5):
        led.record_outcome("saint", True, 1.0)
    assert led.reputation("saint") > led.GRACE_REPUTATION
    assert led.allows_grace("saint")
    assert led.row_weight("saint") == 1.0
    assert led.threshold_scale("saint") == 1.0


def test_quality_of_is_a_clipped_margin():
    q = ReputationLedger.quality_of
    assert q(0.10, 0.10, 0.17) == 1.0              # at baseline: perfect
    assert q(0.10, 0.05, 0.17) == 1.0              # better than baseline
    assert q(0.10, 0.17, 0.17) == 0.0              # at the limit: zero
    mid = q(0.10, 0.135, 0.17)
    assert 0.0 < mid < 1.0


def test_ledger_save_load_roundtrip(tmp_path):
    led = ReputationLedger()
    led.record_outcome("alice", True, 0.9)
    led.record_outcome("alice", False, 0.0)
    led.record_outcome("üser-42", True, 1.0)
    path = str(tmp_path / "trust.json")
    led.save(path)
    back = ReputationLedger.load(path)
    assert back.contributors() == led.contributors()
    for c in led.contributors():
        assert back.reputation(c) == led.reputation(c)
        assert back.stats(c) == led.stats(c)


def test_ledger_load_rejects_unknown_format(tmp_path):
    path = tmp_path / "trust.json"
    path.write_text('{"format": 99, "contributors": {}}\n')
    with pytest.raises(ValueError):
        ReputationLedger.load(str(path))


# --------------------------------------------------------------------------
# store integration: reputation-aware validation
# --------------------------------------------------------------------------

def test_rejected_contribution_freezes_data_but_bumps_trust_version():
    led = ReputationLedger()
    store = RuntimeDataStore(_small_data(), seed=0, trust=led)
    before = (store.fingerprint, store.version, len(store))
    poison = _small_data(user=5)
    poison = RuntimeData(poison.schema, poison.machine_type, poison.X,
                         poison.y * 10.0)          # blatant §III-C.b fail
    report = store.contribute(poison, contributor="eve")
    assert not report.accepted
    assert (store.fingerprint, store.version, len(store)) == before
    assert store.trust_version == led.version > 0  # reputation DID move
    assert led.reputation("eve") < led.NEUTRAL
    assert led.stats("eve").rejected == 1


def test_row_weights_fast_path_and_downweighting():
    led = ReputationLedger()
    data = _small_data().with_contributor("alice")
    store = RuntimeDataStore(data, seed=0, trust=led)
    # all contributors neutral -> None fast path (exact unweighted fits)
    assert store.row_weights(store.data) is None
    assert RuntimeDataStore(_small_data(), seed=0).trust_version == -1
    led.record_outcome("alice", False, 0.0)
    w = store.row_weights(store.data)
    assert w is not None and len(w) == len(store)
    assert np.all((w >= led.MIN_ROW_WEIGHT) & (w < 1.0))
    np.testing.assert_allclose(w, led.row_weight("alice"))


def test_row_weights_pre_provenance_store_uses_unknown_identity():
    led = ReputationLedger()
    store = RuntimeDataStore(_small_data(), seed=0, trust=led)
    led.record_outcome("unknown", False, 0.0)
    w = store.row_weights(store.data)
    assert w is not None and len(w) == len(store)
    np.testing.assert_allclose(w, led.row_weight("unknown"))


def test_low_reputation_contributor_faces_stricter_limit():
    led = ReputationLedger()
    store = RuntimeDataStore(_small_data(), seed=0, trust=led)
    full = store._reject_limit(0.10)
    led.record_outcome("eve", False, 0.0)
    scaled = store._reject_limit(0.10, led.threshold_scale("eve"))
    assert scaled < full
    assert scaled >= full * led.MIN_THRESHOLD_SCALE


def test_grace_accepts_near_miss_but_drains_reputation(monkeypatch):
    led = ReputationLedger()
    for _ in range(3):
        led.record_outcome("alice", True, 1.0)
    assert led.allows_grace("alice")
    rep_before = led.reputation("alice")
    store = RuntimeDataStore(_small_data(), seed=0, trust=led)
    version = store.version
    contrib = _small_data(user=1)
    # a deterministic NEAR-MISS: fails validation but within GRACE_RATIO
    # of the limit (limit = 0.10 * 1.5 + 0.02 = 0.17; 0.20 <= 0.34)
    monkeypatch.setattr(store, "validate",
                        lambda *a, **k: ValidationReport(
                            False, 0.10, 0.20, "machine x: too high"))
    report = store.contribute(contrib, contributor="alice")
    assert report.accepted
    assert "graceful degradation" in report.reason
    assert store.version == version + 1            # data DID enter
    assert led.reputation("alice") < rep_before    # zero-quality outcome
    assert led.stats("alice").accepted == 4


def test_grace_never_stretches_past_the_ratio(monkeypatch):
    led = ReputationLedger()
    for _ in range(3):
        led.record_outcome("alice", True, 1.0)
    store = RuntimeDataStore(_small_data(), seed=0, trust=led)
    monkeypatch.setattr(store, "validate",
                        lambda *a, **k: ValidationReport(
                            False, 0.10, 0.90, "machine x: catastrophic"))
    report = store.contribute(_small_data(user=1), contributor="alice")
    assert not report.accepted                     # 0.90 > 0.17 * 2


# --------------------------------------------------------------------------
# gateway admission: auth + quotas as typed envelopes
# --------------------------------------------------------------------------

def _trust_hub(job="sort"):
    hub = Hub()
    data = _small_data(job)
    hub.publish(JobRepo(job, job, data.schema,
                        RuntimeDataStore(data, seed=0,
                                         trust=ReputationLedger())))
    return hub


def _authed_gateway(rate=1.0, burst=3.0):
    t = [0.0]
    auth = TrustAuthority(rate=rate, burst=burst, clock=lambda: t[0])
    gw = HubGateway(_trust_hub(), PRICES, SCALEOUTS, auth=auth)
    return gw, auth, t


def test_missing_unknown_and_revoked_tokens_are_unauthorized():
    gw, auth, _ = _authed_gateway()
    bare = gw.search(SearchRequest("sort"))        # no wrapper at all
    assert not bare.ok and bare.error_code == "unauthorized"
    assert "AuthedRequest" in bare.detail
    wrong = gw.handle(AuthedRequest("not-a-token", SearchRequest("sort")))
    assert not wrong.ok and wrong.error_code == "unauthorized"
    token = gw.issue_token("alice")
    assert gw.handle(AuthedRequest(token, SearchRequest("sort"))).ok
    assert gw.revoke_token(token)
    stale = gw.handle(AuthedRequest(token, SearchRequest("sort")))
    assert not stale.ok and stale.error_code == "unauthorized"


def test_banned_contributor_is_refused_on_every_token():
    gw, auth, _ = _authed_gateway()
    t1, t2 = gw.issue_token("eve"), gw.issue_token("eve")
    gw.ban_contributor("eve")
    for tok in (t1, t2):
        resp = gw.handle(AuthedRequest(tok, SearchRequest("sort")))
        assert not resp.ok and resp.error_code == "unauthorized"
        assert "banned" in resp.detail
    assert gw.unban_contributor("eve")
    assert gw.handle(AuthedRequest(t1, SearchRequest("sort"))).ok


def test_quota_drains_per_contributor_and_refills_with_the_clock():
    gw, auth, t = _authed_gateway(rate=1.0, burst=3.0)
    # quota is per CONTRIBUTOR: two tokens share one bucket
    tok_a, tok_b = gw.issue_token("alice"), gw.issue_token("alice")
    for tok in (tok_a, tok_b, tok_a):              # 3 = full burst
        assert gw.handle(AuthedRequest(tok, SearchRequest("sort"))).ok
    resp = gw.handle(AuthedRequest(tok_b, SearchRequest("sort")))
    assert not resp.ok and resp.error_code == "quota_exceeded"
    # an unrelated contributor is not starved
    assert gw.handle(AuthedRequest(gw.issue_token("bob"),
                                   SearchRequest("sort"))).ok
    t[0] += 2.0                                    # 2s * 1/s = 2 tokens
    assert gw.handle(AuthedRequest(tok_a, SearchRequest("sort"))).ok


def test_refused_contribute_cannot_move_the_fingerprint_chain():
    gw, auth, t = _authed_gateway(rate=1.0, burst=2.0)
    store = gw.hub.get("sort").store
    before = (store.fingerprint, store.version, store.trust_version)
    sub = _small_data(user=2).subset(np.arange(4))
    req = ContributeRequest("sort", tuple(sub.machine_type),
                            tuple(map(tuple, sub.X)), tuple(sub.y),
                            contributor_id="eve")
    # unauthorized (no token), banned, and quota-exhausted refusals all
    # leave the store untouched: not even a trust outcome is recorded
    assert gw.contribute(req).error_code == "unauthorized"
    tok = gw.issue_token("eve")
    gw.ban_contributor("eve")
    assert gw.contribute(AuthedRequest(tok, req)).error_code \
        == "unauthorized"
    gw.unban_contributor("eve")
    auth._buckets.clear()
    for _ in range(2):                             # drain eve's burst
        gw.handle(AuthedRequest(tok, SearchRequest("sort")))
    assert gw.contribute(AuthedRequest(tok, req)).error_code \
        == "quota_exceeded"
    assert (store.fingerprint, store.version, store.trust_version) == before


def test_token_identity_overrides_spoofed_contributor_id():
    gw, auth, _ = _authed_gateway(rate=50.0, burst=100.0)
    tok = gw.issue_token("alice")
    sub = _small_data(user=2).subset(np.arange(6))
    req = ContributeRequest("sort", tuple(sub.machine_type),
                            tuple(map(tuple, sub.X)), tuple(sub.y),
                            contributor_id="mallory")   # spoof attempt
    resp = gw.contribute(AuthedRequest(tok, req))
    assert resp.ok
    assert resp.result.contributor_id == "alice"
    counts = gw.hub.get("sort").store.data.contributor_counts()
    assert "mallory" not in counts
    if resp.result.accepted:
        assert counts.get("alice") == 6


def test_trust_state_reports_identity_quota_and_reputations():
    gw, auth, t = _authed_gateway(rate=1.0, burst=3.0)
    tok = gw.issue_token("alice")
    gw.hub.get("sort").store.trust.record_outcome("alice", True, 0.8)
    resp = gw.handle(AuthedRequest(tok, TrustStateRequest("alice")))
    assert resp.ok
    got = resp.result
    assert got.contributor_id == "alice" and got.known and not got.banned
    assert got.quota_remaining == pytest.approx(2.0)    # this lookup cost 1
    assert len(got.reputations) == 1
    job, rep, accepted, rejected = got.reputations[0]
    assert job == "sort" and rep > 0.5
    assert (accepted, rejected) == (1, 0)
    # unknown contributor: well-formed, just empty
    other = gw.handle(AuthedRequest(tok, TrustStateRequest("nobody")))
    assert other.ok and not other.result.known
    assert other.result.reputations == ()


def test_unauthenticated_gateway_unwraps_and_reports_unmetered():
    gw = HubGateway(_trust_hub(), PRICES, SCALEOUTS)     # auth=None
    resp = gw.handle(AuthedRequest("whatever", SearchRequest("sort")))
    assert resp.ok                                  # wrapper is transparent
    state = gw.trust_state(TrustStateRequest("alice"))
    assert state.ok and state.result.quota_remaining == float("inf")
    assert not state.result.known
    with pytest.raises(RuntimeError):
        gw.issue_token("alice")                     # no authority to manage


# --------------------------------------------------------------------------
# batch-lane deadlines (satellite: per-request timeout envelopes)
# --------------------------------------------------------------------------

def test_lane_timeout_fails_its_tick_and_the_worker_survives():
    calls = {"n": 0}

    def dispatch(contexts, t_max):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.6)                        # wedge the first tick
        return ["ok"] * len(contexts)

    async def drive():
        lane = BatchLane(dispatch, width=1, timeout_s=0.15)
        lane.start()
        try:
            with pytest.raises(LaneTimeoutError) as err:
                await lane.submit((1.0,), None)
            assert "deadline" in str(err.value)
            return await lane.submit((2.0,), None)  # fresh tick still serves
        finally:
            await lane.stop()

    assert asyncio.run(drive()) == "ok"


def test_async_gateway_maps_lane_timeout_to_typed_envelope():
    gw = HubGateway(_trust_hub(), PRICES, SCALEOUTS)
    calls = {"n": 0}
    ctx = (20.0,)
    svc = gw._service("sort")                      # build + warm up front
    svc.choose_cluster_batch(np.asarray([ctx]), np.asarray([np.nan]))

    class _Slow:
        def choose_cluster_batch(self, contexts, t_max):
            calls["n"] += 1
            if calls["n"] == 1:
                time.sleep(0.6)                    # wedge the first tick
            return svc.choose_cluster_batch(contexts, t_max)

    gw._service = lambda job, seed=None: _Slow()

    async def drive():
        async with AsyncHubGateway(gw, timeout_s=0.15) as agw:
            first = await agw.choose(ChooseRequest("sort", ctx))
            second = await agw.choose(ChooseRequest("sort", ctx))
            return first, second

    first, second = asyncio.run(drive())
    assert not first.ok and first.error_code == "timeout"
    assert "deadline" in first.detail
    assert second.ok and second.result.scale_out in SCALEOUTS


# --------------------------------------------------------------------------
# regression: pre-provenance stores answer contributor_stats well-formed
# --------------------------------------------------------------------------

def test_contributor_stats_on_pre_provenance_store_is_well_formed():
    data = _small_data()
    raw = RuntimeData.from_columns(                 # empty contributor vocab
        data.schema, data.machines, data.codes, data.scale_out,
        data.context, data.runtime, contributors=())
    assert raw.contributors == ()
    hub = Hub()
    hub.publish(JobRepo("sort", "sort", raw.schema,
                        RuntimeDataStore(raw, seed=0)))
    gw = HubGateway(hub, PRICES, SCALEOUTS)
    stats = gw.contributor_stats("sort")
    assert stats.ok
    assert stats.result == (("unknown", len(raw)),)
    assert list(raw.contributor) == ["unknown"] * len(raw)
