"""Workload emulator: Table I structure + runtime-law sanity."""
import numpy as np

from repro.workloads import spark_emul as W

EXPECTED = {"sort": (126, 2), "grep": (162, 3), "sgd": (180, 4),
            "kmeans": (180, 4), "pagerank": (282, 4)}


def test_table1_structure():
    total = 0
    for job, (n, nfeat) in EXPECTED.items():
        d = W.generate_job_data(job)
        assert len(d) == n, f"{job}: {len(d)} != {n}"
        assert d.X.shape[1] == nfeat
        total += len(d)
    assert total == 930                          # the paper's 930 jobs


def test_runtimes_positive_and_decreasing_in_scaleout():
    for job in EXPECTED:
        d = W.generate_job_data(job)
        assert (d.y > 0).all()
    # noise-free law: more nodes never catastrophically slower for sort
    t = [W.true_runtime("sort", "m5.xlarge", s, (15.0,)) for s in (2, 4, 8)]
    assert t[0] > t[1] > t[2]


def test_memory_cliff():
    """Iterative jobs fall off a cliff when the dataset misses memory
    (paper §IV-B: insufficient scale-out -> disk thrashing)."""
    small = W.true_runtime("sgd", "c5.xlarge", 8, (30.0, 50, 100))
    tiny = W.true_runtime("sgd", "c5.xlarge", 2, (30.0, 50, 100))
    # 2 nodes x 8GB cannot hold 30GB*2.3 -> penalized beyond the 4x scaleup
    assert tiny > small * 4.0


def test_context_groups_are_local_datasets():
    d = W.generate_job_data("kmeans")
    groups = W.context_groups(d)
    # 10 sampled (size, k, dim) cells collapse to the unique (k, dim) pairs
    assert 2 <= len(groups) <= 10
    assert sum(len(g) for g in groups) == len(d)
    assert all(len(g) >= 6 for g in groups)


def test_measurement_median_controls_stragglers():
    vals = [W._measure("sort", "m5.xlarge", 4, (15.0,), seed=s)
            for s in range(30)]
    base = W.true_runtime("sort", "m5.xlarge", 4, (15.0,))
    # medians sit near the true law despite straggler injection
    assert np.median(vals) < base * 1.15
